"""Microbenchmark: per-RPC cost of the zero-handoff fast path.

Isolates the cost of ONE synchronous RPC (``AsyncRpc`` + ``Wait``) between
two services, with no service-time model attached — the pure dispatch
constant the paper's 6x fiber win comes from shrinking.  Per backend it
reports ns/call for the default configuration (fast path on for the
cooperative backends) and, for every cooperative backend, a second
``+noinline`` row with ``App.inline_budget = 0`` — the PR 3 carrier path —
so the fast-path win is quoted against the repo's own previous design, not
just against threads.

CSV rows (``name,us_per_call,derived``):

    rpc_path/fiber,1.85,ns=1850 inline=20480 spawns=0
    rpc_path/fiber+noinline,31.40,ns=31398 inline=0 spawns=20480
    rpc_path/fiber_fastpath_speedup,16.97,x_vs_noinline
    rpc_path/fiber+resilient,3.90,ns=3902 inline=20480 spawns=0
    rpc_path/fiber_resilient_overhead,2.11,x_vs_plain

The ``*_fastpath_speedup`` rows are the acceptance metric for PR 4:
inlined cooperative calls must come in >= 2x cheaper than the same
backend's carrier path.  The ``+resilient`` rows (PR 7) rerun the inline
configuration with a full breakers + budgeted-retry + bulkhead policy:
since the fast path became breaker-aware, the policy adds per-call
bookkeeping (deadline stamp, breaker window, bulkhead slot) instead of
forcing the carrier path, and the ``*_resilient_overhead`` ratio quotes
that bookkeeping — the PR 7 acceptance bound is <= 3x the plain ns/call,
with ``inline=`` proving the fast path stayed engaged.

The ``+hooks`` rows (PR 10) price the ``repro.core.instrument`` seam the
concurrency sanitizer rides on: an interleaved paired probe of the same
inline cell with the seam disabled (``hooks is None``, the shipped
default — one predicted-false branch per event site) vs a no-op
``Hooks()`` instance installed.  The seam's design claim is that the
*disabled* side is free: that claim is enforced by the existing
hard-gated ``rpc_path/<backend>`` trend cells (which always run hooks-
disabled, so any seam cost shows up against the committed baseline), and
the paired ``*_hook_toll`` ratio quotes what turning the hooks ON costs —
warn-only trend data, since a no-op-dispatch toll is diagnostic, not a
shipped configuration.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

from repro.core import (App, AsyncRpc, BACKEND_NAMES, ResiliencePolicy,
                        RetryPolicy, ServiceSpec, Wait)

# backends whose AsyncRpc path the fast path accelerates.  Thread-family
# backends keep the full carrier path by design; fiber-batch and
# fiber-batch-cq are excluded because their submission rings intercept
# AsyncRpc before the inline path, so an inline-on/off comparison there
# measures nothing but noise.
INLINE_BACKENDS = ("fiber", "fiber-steal", "event-loop", "event-loop-shard")

# backends the instrumentation-seam probe prices: one inline-path
# representative (fiber has the lowest ns/call, so a seam regression is
# proportionally largest there) and one carrier-path representative.
HOOK_PROBE_BACKENDS = ("thread", "fiber")


def _leaf(svc, payload):
    return payload
    yield  # pragma: no cover - marks this as a generator


def _chain(svc, payload):
    """The measured loop: `payload` back-to-back synchronous RPCs."""
    acc = 0
    for i in range(payload):
        f = yield AsyncRpc("leaf", "echo", i)
        acc += yield Wait(f)
    return acc


def resilient_policy() -> ResiliencePolicy:
    """The policy priced by the ``+resilient`` rows: breakers + budgeted
    retries + a bulkhead, with a deadline far above the per-call cost so
    the measurement never trips the machinery it is pricing."""
    return ResiliencePolicy(deadline=5.0, breakers=True, bulkhead=1024,
                            retry=RetryPolicy())


def _build(backend: str, inline: bool,
           resilience: Optional[ResiliencePolicy] = None) -> App:
    app = App(backend=backend, resilience=resilience)
    if not inline:
        app.inline_budget = 0  # PR 3 carrier path
    app.add_service(ServiceSpec("leaf", {"echo": _leaf}, n_workers=1))
    app.add_service(ServiceSpec("driver", {"run": _chain}, n_workers=1))
    return app


def measure_rpc_cost(backend: str, *, inline: bool = True,
                     resilience: Optional[ResiliencePolicy] = None,
                     calls_per_req: int = 64, iters: int = 20,
                     warmup_iters: int = 3) -> Dict[str, float]:
    """Wall time per synchronous leaf RPC issued from inside a handler."""
    with _build(backend, inline, resilience) as app:
        for _ in range(warmup_iters):
            app.send("driver", "run", calls_per_req).wait(timeout=30)
        t0 = time.perf_counter()
        for _ in range(iters):
            app.send("driver", "run", calls_per_req).wait(timeout=30)
        dt = time.perf_counter() - t0
        stats = app.backend_stats()
    return {
        "ns_per_call": dt / (iters * calls_per_req) * 1e9,
        "inline_calls": stats.inline_calls,
        "spawns": stats.spawns,
        "fast_futures": stats.fast_futures,
        "slow_futures": stats.slow_futures,
    }


def measure_hook_toll(backend: str, *, iters: int = 20,
                      calls_per_req: int = 64,
                      rounds: int = 3) -> Dict[str, float]:
    """Interleaved paired probe of the instrumentation seam: the same
    rpc_path cell with hooks disabled (the shipped default) vs a no-op
    :class:`repro.core.instrument.Hooks` installed.  Best-of across
    ``rounds`` alternating-order pairs (the repo's A/B discipline: both
    sides see the same machine weather, best-vs-best), so the ratio is a
    paired same-run number, not two noisy absolutes."""
    from repro.core import instrument
    best = {"off": float("inf"), "on": float("inf")}
    for i in range(rounds):
        order = ("off", "on") if i % 2 == 0 else ("on", "off")
        for side in order:
            if side == "on":
                instrument.install(instrument.Hooks())
            try:
                r = measure_rpc_cost(backend, iters=iters,
                                     calls_per_req=calls_per_req)
            finally:
                if side == "on":
                    instrument.uninstall()
            best[side] = min(best[side], r["ns_per_call"])
    return {
        "off_ns": best["off"],
        "on_ns": best["on"],
        "toll": best["on"] / max(best["off"], 1e-9),
    }


def run(quick: bool = False,
        backends: Optional[List[str]] = None) -> List[str]:
    iters = 6 if quick else 20
    rows: List[str] = []
    res: Dict[str, Dict[str, float]] = {}
    backends = list(backends) if backends else list(BACKEND_NAMES)
    for backend in backends:
        r = measure_rpc_cost(backend, iters=iters)
        res[backend] = r
        rows.append(f"rpc_path/{backend},{r['ns_per_call'] / 1e3:.2f},"
                    f"ns={r['ns_per_call']:.0f}"
                    f" inline={r['inline_calls']:.0f}"
                    f" spawns={r['spawns']:.0f}")
    for backend in backends:
        if backend not in INLINE_BACKENDS:
            continue
        r = measure_rpc_cost(backend, inline=False, iters=iters)
        res[backend + "+noinline"] = r
        rows.append(f"rpc_path/{backend}+noinline,"
                    f"{r['ns_per_call'] / 1e3:.2f},"
                    f"ns={r['ns_per_call']:.0f}"
                    f" inline={r['inline_calls']:.0f}"
                    f" spawns={r['spawns']:.0f}")
        speedup = r["ns_per_call"] / max(
            res[backend]["ns_per_call"], 1e-9)
        rows.append(f"rpc_path/{backend}_fastpath_speedup,"
                    f"{speedup:.2f},x_vs_noinline")
    for backend in backends:
        if backend not in INLINE_BACKENDS:
            continue
        r = measure_rpc_cost(backend, resilience=resilient_policy(),
                             iters=iters)
        res[backend + "+resilient"] = r
        rows.append(f"rpc_path/{backend}+resilient,"
                    f"{r['ns_per_call'] / 1e3:.2f},"
                    f"ns={r['ns_per_call']:.0f}"
                    f" inline={r['inline_calls']:.0f}"
                    f" spawns={r['spawns']:.0f}")
        overhead = r["ns_per_call"] / max(
            res[backend]["ns_per_call"], 1e-9)
        rows.append(f"rpc_path/{backend}_resilient_overhead,"
                    f"{overhead:.2f},x_vs_plain")
    for backend in backends:
        if backend not in HOOK_PROBE_BACKENDS:
            continue
        t = measure_hook_toll(backend, iters=iters,
                              rounds=2 if quick else 3)
        rows.append(f"rpc_path/{backend}+hooks,"
                    f"{t['on_ns'] / 1e3:.2f},"
                    f"ns={t['on_ns']:.0f} off_ns={t['off_ns']:.0f}")
        rows.append(f"rpc_path/{backend}_hook_toll,"
                    f"{t['toll']:.2f},x_vs_disabled")
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
