"""CI bench-smoke: tiny-duration sweep of every app × backend cell.

Drives the full ``repro.apps.REGISTRY`` × ``BENCH_BACKENDS`` matrix the way
the real benchmarks do, but at smoke scale, and writes a JSON artifact for
CI.  Per cell it checks:

1. **semantic parity** — a fixed, seeded request sequence must return results
   identical to the ``thread`` baseline (the paper's migration invariant);
2. **liveness under load** — ``SMOKE_TRIALS`` tiny open-loop trials must
   complete with zero errors; per-trial achieved rps and the per-backend
   counters (steals, pool stalls, queue depth, batch flushes, ring
   occupancy) are recorded.  The repeated trials exist for the **trend
   gate**: their spread is the per-cell noise estimate ``benchmarks/
   trend.py`` uses to size the regression band when diffing this artifact
   against a previous run's (the same idea as the steal probe's interleaved
   paired trials — never compare one noisy number to another without a
   same-run noise measurement).

The artifact carries a ``schema_version`` and a normalized ``records`` list
so cross-run comparison does not depend on the human-oriented ``cells``
layout staying stable.  Each app x backend cell contributes TWO records —
``achieved_rps`` (direction ``higher``, hard-gated) and ``p99_ms``
(direction ``lower``, **warn-only**: a smoke-scale tail is ~a hundred
samples and swings several-x run-over-run on identical code, so an
out-of-band move is surfaced loudly in the trend output rather than
failing the run; hard p99 gating lives in ``trend.py --from-csv`` over
full-bench CSVs) — and the ``rpc_path`` microbenchmark (see
``bench_rpc_path.py``) contributes one hard-gated ``ns_per_call`` record
per backend (best-of-3 cheap trials, wide cross-hardware clamps), giving
every future PR a per-RPC-cost trend line.

It also runs the **work-stealing probe**: interleaved paired trials of
``fiber`` vs ``fiber-steal`` at ``n_workers=4`` on every app, stopping early
once fiber-steal's best throughput reaches round-robin fiber's.  Paired,
adjacent-in-time trials are used because absolute throughput on shared CI
runners is noisy; the probe result is recorded in the artifact.  The same
paired-peak machinery drives the **design-point probes** (``DESIGN_PROBES``):
``fiber-batch-cq`` vs ``fiber-batch`` on the fan-out-heavy mediaservice
(reply/delivery batching must be worth >= 1.3x peak on the mixed stream of
sequential joins) and ``event-loop-shard`` vs ``event-loop`` on
hotelreservation's CPU-heavy reserve path (sharding must lift the
Compute-serialization ceiling).

It also runs one **overload probe** (breakers-on vs breakers-off on
socialnetwork at ``OVERLOAD_MULTIPLE``x the measured peak, scored on
goodput — see ``_overload_probe``); its goodput records enter the trend
gate with their own wide ``overload`` noise band.  A **collapse-knee
probe** (PR 7) runs ``bench_overload.measure_collapse_sweep`` at smoke
scale on the same app and records the knee multiple as a warn-only trend
cell — at smoke scale the knee is as bimodal as the goodput it derives
from, so it is surfaced, never gated; the per-cell knees live in the full
bench's ``launch_results/overload_sweep.json`` artifact.  The rpc_path
records additionally include a ``+resilient`` cell per cooperative
backend (full breakers + retry + bulkhead policy with the fast path
engaged), giving the breaker-aware inline admission cost its own trend
line next to the plain ns/call.

PR 8 adds the **skewed-cache cells**: every app × backend additionally
runs the Zipfian session-affine ``cached`` workload (cache-aside tier with
hit-rate-dependent service time) and records the per-cell
``cache_hit_rate`` as a warn-only gauge — the hit rate is a property of
the key distribution and the cache tier, not the scheduler, so a move
flags a workload/cache change rather than a backend regression.  A
**session-pinning probe** A/Bs ``event-loop-shard`` placement policy on
the same workload: by-session (deterministic, state-affine routing on
``RequestContext.session``) vs by-ticket (spread-everything), interleaved
and peak-vs-peak like every other probe, recorded warn-only.

PR 9 adds the **sick-dependency probe** (``_faults_probe``): a seeded
``FaultPlan`` brownout on socialnetwork's ``post_storage.store`` edge at a
fixed sub-peak rate, breakers-on vs breakers-off, scored on goodput.  The
scenario is engineered to be deterministic (100%-failing sick edge, seeded
plan, shared arrival seed), so unlike the uniform-overload probe its
``breaker_win`` record is **hard-gated** and a win under
``FAULTS_WIN_TARGET`` fails the run.  The pinning probe also gains a
**cold-start-per-round** variant (fresh apps every round: first-touch
placement included), recorded as its own warn-only trend cells next to the
warm mode's.

The process exits non-zero iff a cell errors, parity is violated, or the
sick-dependency probe's breaker win misses its target — the
steal/design/overload/pinning probes and the raw numbers are artifact
data, not gates.

Usage (what .github/workflows/ci.yml runs):
    PYTHONPATH=src python -m benchmarks.run --smoke --json smoke.json \
        [--app socialnetwork]
"""
from __future__ import annotations

import json
import sys
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.apps import APP_NAMES, BENCH_BACKENDS, get_app_def
from repro.core import BackendStats, RequestContext, run_trial, warmup

BASELINE = "thread"

# Artifact schema: bump when the *records* shape changes incompatibly.
# benchmarks/trend.py refuses to diff artifacts with a different major.
SCHEMA_VERSION = 2

# smoke scale: small enough for a CI lane, large enough to exercise
# saturation paths (the pool's bounded queue, the steal path, batch rings).
SMOKE_RATE = 300.0
SMOKE_DURATION = 0.4
SMOKE_TRIALS = 2    # repeated per cell: best-of is the headline, the spread
                    # is the trend gate's per-cell noise estimate
PARITY_REQUESTS = 4
PROBE_RATE = 4000.0
PROBE_DURATION = 0.25
# The probe stops at the first round where fiber-steal's best >= fiber's,
# so a generous budget only costs wall time when the comparison is losing —
# and each extra paired round is another chance for the maxima to converge
# on noisy shared runners.
PROBE_MAX_ROUNDS = 16
PROBE_MAX_OUTSTANDING = 128


def _fixed_requests(app_name: str, workload: str = "mixed",
                    n: int = PARITY_REQUESTS) -> List[Any]:
    factory = get_app_def(app_name).make_request_factory(workload)
    rng = np.random.default_rng(12)
    return [factory(rng) for _ in range(n)]


def _smoke_cell(app_name: str, backend: str,
                requests: List[Any]) -> Dict[str, Any]:
    """One app × backend cell: fixed requests (for parity) + repeated tiny
    trials (best-of for the headline, spread for the trend noise band)."""
    d = get_app_def(app_name)
    factory = d.make_request_factory("mixed")
    with d.build(backend, n_workers=2, frontend_workers=4) as app:
        results = []
        for req in requests:  # a 4-tuple request carries a session id
            dest, method, payload = req[:3]
            ctx = RequestContext(session=req[3]) if len(req) > 3 else None
            results.append(app.send(dest, method, payload,
                                    ctx=ctx).wait(timeout=30))
        warmup(app, factory)
        # monotonic counters are the delta across exactly the measured
        # trials — parity requests and warmup traffic excluded — so
        # counter-per-second rates line up with the rps reported next to
        # them.  Gauges (queue_depth_hwm, ring_hwm) are executor-lifetime
        # high-waters by definition (BackendStats.delta keeps `after`), so
        # they may still reflect the warmup burst.
        stats_before = app.backend_stats()
        trials = [run_trial(app, factory, SMOKE_RATE, SMOKE_DURATION,
                            seed=3 + i) for i in range(SMOKE_TRIALS)]
        stats = BackendStats.delta(stats_before, app.backend_stats())
    best = max(trials, key=lambda t: t.achieved_rps)
    # p99 "best" is the *lowest* tail across trials, mirroring best-of rps:
    # both ask "what did this cell do on its best run", and the trial spread
    # still feeds the trend gate's noise band.
    p99s = [t.p99 * 1e3 for t in trials if np.isfinite(t.p99)]
    return {
        "status": "ok",
        "results": results,
        "achieved_rps": round(best.achieved_rps, 1),
        "trial_rps": [round(t.achieved_rps, 1) for t in trials],
        "p99_ms": round(min(p99s), 3) if p99s else None,
        "trial_p99_ms": [round(p, 3) for p in p99s],
        "completed": sum(t.completed for t in trials),
        "errors": sum(t.errors for t in trials),
        "shed": sum(t.shed for t in trials),
        "backend_stats": {k: round(v, 6) for k, v in
                          stats.as_dict().items()},
    }


def _cache_cell(app_name: str, backend: str) -> Dict[str, Any]:
    """One skewed-cache cell: the Zipfian session-affine ``cached``
    workload at smoke scale.  Request errors are smoke failures like any
    cell's; the hit rate enters the trend gate as a warn-only gauge (it
    drifts with achieved rps — a slower run reuses hot keys less before
    the trial window closes — so it can inform but not gate)."""
    d = get_app_def(app_name)
    factory = d.make_request_factory("cached")
    with d.build(backend, n_workers=2, frontend_workers=4) as app:
        warmup(app, factory)
        stats_before = app.backend_stats()
        tr = run_trial(app, factory, SMOKE_RATE, SMOKE_DURATION, seed=11)
        stats = BackendStats.delta(stats_before, app.backend_stats())
    hits, misses = int(stats.cache_hits), int(stats.cache_misses)
    looked = hits + misses
    return {
        "status": "ok",
        "achieved_rps": round(tr.achieved_rps, 1),
        "completed": tr.completed,
        "errors": tr.errors,
        "cache_hits": hits,
        "cache_misses": misses,
        "hit_rate": round(hits / looked, 4) if looked else None,
    }


def _paired_probe(app_name: str, base: str, cand: str, *,
                  target: float = 1.0, workload: str = "mixed",
                  rate: float = PROBE_RATE,
                  max_outstanding: int = PROBE_MAX_OUTSTANDING,
                  max_rounds: int = PROBE_MAX_ROUNDS,
                  build=None, metric=None,
                  trial_kwargs: Optional[Dict[str, Any]] = None,
                  cold_start: bool = False) -> Dict[str, Any]:
    """Interleaved paired peak probe of two configurations on one app.

    The repo's A/B discipline for backend claims (see ROADMAP): trials are
    interleaved (alternating order each round) so both sides see the same
    runner weather, the comparison is peak-vs-peak (best across rounds),
    and the probe stops early once ``cand``'s best reaches ``target`` x
    ``base``'s best — a generous round budget only costs wall time when the
    claim is losing.

    ``base``/``cand`` are backend names by default, but any labels work
    with a custom ``build(label)`` (e.g. ``breakers-on``/``breakers-off``
    for the overload probe).  ``metric`` picks the scored TrialResult field
    (default achieved rps); ``trial_kwargs`` is forwarded to ``run_trial``
    (e.g. ``deadline``/``enforce_deadline`` for goodput probes).

    ``cold_start=True`` rebuilds (and re-warms) both apps every round
    instead of keeping them alive across the whole probe: each round then
    measures a freshly-started system — no cache contents, shard state or
    executor high-waters accumulated from earlier rounds.  The per-round
    warmup stays (it touches the Compute calibration and every code path,
    same as a fresh CI cell), so "cold" means *no cross-round carryover*,
    not "never executed".
    """
    d = get_app_def(app_name)
    factory = d.make_request_factory(workload)
    if metric is None:
        def metric(tr):
            return tr.achieved_rps
    kwargs = dict(trial_kwargs or {})
    if build is None:
        def build(b):  # canonical benchmark sizing for each backend family
            from repro.apps import build_bench_app
            return build_bench_app(app_name, b)
    apps: Dict[str, Any] = {}
    best = {base: 0.0, cand: 0.0}
    rounds_used = 0
    stats: Dict[str, Any] = {}

    def _open() -> None:
        for b in best:
            apps[b] = build(b)
            apps[b].start()
            warmup(apps[b], factory)

    def _close() -> None:
        for app in apps.values():
            app.stop()
        apps.clear()

    try:
        if not cold_start:
            _open()
        for i in range(max_rounds):
            rounds_used = i + 1
            if cold_start:
                _open()
            order = ((base, cand) if i % 2 == 0 else (cand, base))
            for b in order:
                tr = run_trial(apps[b], factory, rate, PROBE_DURATION,
                               seed=20 + i, drain=1.0,
                               max_outstanding=max_outstanding, **kwargs)
                best[b] = max(best[b], metric(tr))
            stats = {b: apps[b].backend_stats() for b in best}
            if cold_start:
                _close()
            if best[base] > 0 and best[cand] >= target * best[base]:
                break
    finally:
        _close()
    ratio = best[cand] / best[base] if best[base] > 0 else float("inf")
    return {
        "base": base,
        "cand": cand,
        "workload": workload,
        "base_peak_rps": round(best[base], 1),
        "cand_peak_rps": round(best[cand], 1),
        "ratio": round(ratio, 3) if best[base] > 0 else None,
        "target": target,
        "rounds": rounds_used,
        # a dead base (0 rps) is an invalid comparison, not a win
        "ok": best[base] > 0 and ratio >= target,
        "_stats": stats,  # stripped before the artifact; for probe wrappers
    }


def _steal_probe(app_name: str,
                 max_rounds: int = PROBE_MAX_ROUNDS) -> Dict[str, Any]:
    """Paired fiber vs fiber-steal throughput at n_workers=4 (legacy probe
    shape kept for artifact continuity)."""
    d = get_app_def(app_name)
    probe = _paired_probe(
        app_name, "fiber", "fiber-steal", max_rounds=max_rounds,
        build=lambda b: d.build(b, n_workers=4, frontend_workers=4))
    return {
        "fiber_peak_rps": probe["base_peak_rps"],
        "fiber_steal_peak_rps": probe["cand_peak_rps"],
        "steals": probe["_stats"]["fiber-steal"].steals,
        "rounds": probe["rounds"],
        "ok": probe["ok"],
    }


# Design-point probes for the ring/shard backends (PR 5): each new design
# variant is held against the backend it refines, on the app whose shape it
# targets, at a rate that saturates both even on a fast machine (at a
# sub-saturating rate both sides pin to the offered rate and the comparison
# is vacuous).  fiber-batch-cq must beat fiber-batch by >= 1.3x peak on the
# fan-out-heavy mediaservice (its read-dominated mix is a stream of
# sequential joins — exactly the one-wakeup-per-reply regime the completion
# ring amortizes; compose's WaitAll latches already coalesce to one wakeup,
# so the win lives in the mix, not the write path).  event-loop-shard must
# beat the single-loop event-loop on hotelreservation's reserve path (the
# CPU-heavy auth leaf is the Compute-serialization ceiling sharding lifts;
# on the sleep-dominated mixed path the single loop's coalesced timer
# wakeups win instead — that trade is recorded in ROADMAP.md).  The deeper
# max_outstanding keeps the open loop offering work through the saturation
# transient instead of shedding the comparison away.  Probe results are
# artifact data, not gates, like the steal probe.
DESIGN_PROBES: Dict[str, List[Dict[str, Any]]] = {
    "mediaservice": [dict(base="fiber-batch", cand="fiber-batch-cq",
                          target=1.3, workload="mixed", rate=12000.0,
                          max_outstanding=256)],
    "hotelreservation": [dict(base="event-loop", cand="event-loop-shard",
                              target=1.0, workload="reserve", rate=12000.0,
                              max_outstanding=256)],
}


def _design_probes(app_name: str,
                   max_rounds: int = PROBE_MAX_ROUNDS) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    for spec in DESIGN_PROBES.get(app_name, []):
        cand = spec["cand"]
        probe = _paired_probe(app_name, spec["base"], cand,
                              target=spec["target"],
                              workload=spec["workload"], rate=spec["rate"],
                              max_outstanding=spec["max_outstanding"],
                              max_rounds=max_rounds)
        stats = probe.pop("_stats")
        if cand == "fiber-batch-cq":
            st = stats[cand]
            flushes = (st.cq_flushes_size + st.cq_flushes_timeout
                       + st.cq_flushes_idle)
            probe["completions_batched"] = st.completions_batched
            probe["cq_mean_batch"] = round(
                st.completions_batched / flushes, 2) if flushes else None
        if cand == "event-loop-shard":
            probe["shards"] = stats[cand].shards
        out[cand] = probe
    return out


# Overload probe (PR 6): breakers-on vs breakers-off at a fixed multiple of
# the measured peak, scored on GOODPUT (completions within the per-request
# deadline / s) rather than raw rps — raw throughput past the peak rewards
# completing requests nobody is still waiting for.  Both sides run the same
# resilience policy (deadlines + budgeted retries); only the per-edge
# circuit breakers differ, so the ratio isolates what fail-fast buys (or
# costs) when the app is drowning.  Probe data, not a gate — a measured
# loss is recorded honestly (see ROADMAP), and the goodput records feed the
# cross-run trend gate with their own wide "overload" noise band.
OVERLOAD_PROBE_APP = "socialnetwork"
OVERLOAD_PROBE_BACKEND = "fiber"
OVERLOAD_MULTIPLE = 3.0


def _overload_probe(max_rounds: int = PROBE_MAX_ROUNDS) -> Dict[str, Any]:
    from repro.apps import build_bench_app
    from repro.core import (ResiliencePolicy, RetryPolicy,
                            find_peak_throughput)
    app_name = OVERLOAD_PROBE_APP
    d = get_app_def(app_name)
    deadline = d.deadlines.get("mixed", 0.08)
    factory = d.make_request_factory("mixed")
    # cheap peak ramp on a plain (no-resilience) app: the overload rate is
    # a multiple of what the healthy app can actually do on this runner
    with build_bench_app(app_name, OVERLOAD_PROBE_BACKEND) as app:
        warmup(app, factory)
        pk = find_peak_throughput(app, factory, start_rate=200, growth=1.7,
                                  duration=0.3, max_trials=10)
    rate = OVERLOAD_MULTIPLE * pk.peak_rps

    def build(label: str):
        pol = ResiliencePolicy(deadline=deadline, retry=RetryPolicy(),
                               breakers=(label == "breakers-on"))
        return build_bench_app(app_name, OVERLOAD_PROBE_BACKEND,
                               resilience=pol)

    probe = _paired_probe(app_name, "breakers-off", "breakers-on",
                          rate=rate, max_outstanding=1024,
                          max_rounds=max_rounds, build=build,
                          metric=lambda tr: tr.goodput_rps,
                          trial_kwargs=dict(deadline=deadline,
                                            enforce_deadline=True,
                                            settle=0.5))
    on = probe.pop("_stats")["breakers-on"]
    probe.update(backend=OVERLOAD_PROBE_BACKEND, metric="goodput_rps",
                 peak_rps=round(pk.peak_rps, 1),
                 overload_rps=round(rate, 1), deadline_s=deadline,
                 breaker_opens=on.breaker_opens, timeouts=on.timeouts,
                 retries=on.retries, rejections=on.rejections)
    return probe


# Session-pinning probe (PR 8): by-session vs by-ticket shard placement on
# the event-loop-shard backend under the Zipfian ``cached`` workload — the
# hot-shard regime, where session affinity concentrates the popular keys'
# sessions on few shards.  Affinity buys shard-local per-session state and
# deterministic placement; the probe measures what it costs (or wins) in
# raw throughput against spread-everything ticket placement.  Probe data,
# not a gate: a measured loss is recorded honestly, per the ROADMAP's A/B
# discipline.
PINNING_PROBE_APP = "socialnetwork"
PINNING_PROBE_BACKEND = "event-loop-shard"


def _pinning_probe(max_rounds: int = PROBE_MAX_ROUNDS,
                   cold_start: bool = False) -> Dict[str, Any]:
    """``cold_start=True`` is the PR 9 variant: fresh apps every round, so
    the placement A/B includes first-touch behavior — by-session routing
    concentrates the cold misses of the hot keys on few shards, while the
    warm (keep-alive) mode mostly measures steady-state hit traffic.  Both
    modes are recorded as distinct warn-only trend cells."""
    from repro.apps import build_bench_app
    from repro.core import find_peak_throughput

    def build(label: str):
        app = build_bench_app(PINNING_PROBE_APP, PINNING_PROBE_BACKEND)
        # the A/B lever: deliver-time routing flag, same app otherwise
        app.shard_by_session = (label == "by-session")
        return app

    # cheap peak ramp first (same pattern as the overload probe): the cached
    # workload is sleep-dominated and very fast on this backend, so a fixed
    # rate would sit far below saturation and pin both sides to the offered
    # rate — a vacuous comparison.  Probing *at* the measured peak is where
    # placement policy can actually move throughput.
    d = get_app_def(PINNING_PROBE_APP)
    factory = d.make_request_factory("cached")
    with build("by-ticket") as app:
        warmup(app, factory)
        pk = find_peak_throughput(app, factory, start_rate=2000, growth=1.7,
                                  duration=0.3, max_trials=10)

    probe = _paired_probe(PINNING_PROBE_APP, "by-ticket", "by-session",
                          workload="cached", rate=pk.peak_rps,
                          max_outstanding=1024, max_rounds=max_rounds,
                          build=build, cold_start=cold_start)
    stats = probe.pop("_stats")
    probe.update(backend=PINNING_PROBE_BACKEND,
                 mode="cold-start" if cold_start else "warm",
                 probe_rate=round(pk.peak_rps, 1),
                 shards=stats["by-session"].shards,
                 cache_hits=int(stats["by-session"].cache_hits),
                 cache_misses=int(stats["by-session"].cache_misses))
    return probe


# Sick-dependency probe (PR 9): the deterministic breakers-pay-off check.
# A seeded FaultPlan brownout (bench_faults' scenario, single-sourced from
# that module) degrades socialnetwork's post_storage.store edge at a fixed
# sub-peak rate; breakers-on vs breakers-off goodput is the win.  Unlike
# the uniform-overload probe above (warn-only: bimodal at smoke scale),
# this scenario is *engineered* to be deterministic — the sick edge fails
# 100% of the time, the fault plan is seeded, both sides see the same
# arrivals — so its records enter the trend gate HARD (no warn-only), and
# a win below FAULTS_WIN_TARGET fails the smoke run outright.  The
# recorded win is capped at FAULTS_WIN_CAP: past ~3x the off side's
# goodput is a near-zero denominator and the raw ratio swings orders of
# magnitude run-over-run, which a hard trend gate cannot tolerate; every
# capped value reads "decisive win", and any real regression pulls the
# value under the cap long before it threatens the target.
FAULTS_PROBE_APP = "socialnetwork"
FAULTS_PROBE_BACKEND = "fiber"
FAULTS_WIN_TARGET = 1.3
FAULTS_WIN_CAP = 3.0
FAULTS_RATE_FRACTION = 0.6
FAULTS_PROBE_DURATION = 0.6
FAULTS_PROBE_ROUNDS = 2


def _faults_probe(rounds: int = FAULTS_PROBE_ROUNDS) -> Dict[str, Any]:
    from repro.apps import build_bench_app
    from repro.core import ResiliencePolicy, find_peak_throughput
    from .bench_faults import _sick_plan
    app_name = FAULTS_PROBE_APP
    backend = FAULTS_PROBE_BACKEND
    d = get_app_def(app_name)
    deadline = d.deadlines.get("mixed", 0.08)
    factory = d.make_request_factory("mixed")
    # cheap healthy ramp: the probe drives a comfortably-sustainable rate —
    # the scenario is "one dependency is sick", not "the app is drowning"
    with build_bench_app(app_name, backend) as app:
        warmup(app, factory)
        pk = find_peak_throughput(app, factory, start_rate=200, growth=1.7,
                                  duration=0.3, max_trials=10)
    rate = max(FAULTS_RATE_FRACTION * pk.peak_rps, 50.0)

    def _side(breakers: bool) -> Any:
        pol = ResiliencePolicy(deadline=deadline, retry=None,
                               breakers=breakers)
        app = build_bench_app(app_name, backend, resilience=pol)
        with app:
            warmup(app, factory)          # healthy warmup, then get sick
            app.set_faults(_sick_plan(app_name))
            tr = run_trial(app, factory, rate, FAULTS_PROBE_DURATION,
                           seed=31, drain=0.5, deadline=deadline,
                           enforce_deadline=True, settle=0.5,
                           arm_faults=True)
            by_edge = app.resilience_by_edge()
        return tr, by_edge
    sick_edge = tuple(d.fault_targets["sick"])
    healthy_edge = tuple(d.fault_targets["healthy"])
    wins: List[float] = []
    last: Dict[str, Any] = {}
    for i in range(rounds):
        # interleaved like every probe: alternate which side runs first
        order = (True, False) if i % 2 == 0 else (False, True)
        side: Dict[bool, Any] = {}
        for breakers in order:
            side[breakers] = _side(breakers)
        tr_on, edges_on = side[True]
        tr_off, _ = side[False]
        win = tr_on.goodput_rps / max(tr_off.goodput_rps, 1e-9)
        wins.append(round(min(win, FAULTS_WIN_CAP), 3))
        last = {
            "on_goodput_rps": round(tr_on.goodput_rps, 1),
            "off_goodput_rps": round(tr_off.goodput_rps, 1),
            "raw_win": round(win, 3),
            "sick_edge_opens": int(
                edges_on.get(sick_edge, {}).get("opens", 0)),
            "healthy_edge_opens": int(
                edges_on.get(healthy_edge, {}).get("opens", 0)),
            "faults_injected": int(
                tr_on.backend_stats.get("faults_injected", 0)),
        }
        if max(wins) >= FAULTS_WIN_CAP:
            break  # decisive; further rounds only cost wall time
    value = max(wins)
    return {
        "app": app_name,
        "backend": backend,
        "workload": "mixed",
        "metric": "goodput_rps",
        "rate_rps": round(rate, 1),
        "deadline_s": deadline,
        "target": FAULTS_WIN_TARGET,
        "cap": FAULTS_WIN_CAP,
        "win": value,
        "wins": wins,
        "rounds": len(wins),
        "ok": value >= FAULTS_WIN_TARGET,
        **last,
    }


def _knee_probe() -> Dict[str, Any]:
    """Smoke-scale collapse-knee sweep (see ``bench_overload``): one cell
    (the overload probe's app x backend), 2-5x the measured peak, reported
    as the knee multiple.  Warn-only trend data — at smoke scale the knee
    inherits the goodput bimodality of its parent metric."""
    from .bench_overload import measure_collapse_sweep
    return measure_collapse_sweep(OVERLOAD_PROBE_APP,
                                  OVERLOAD_PROBE_BACKEND,
                                  peak_duration=0.25, duration=0.3)


def _rpc_path_records(out: Dict[str, Any]) -> None:
    """Per-RPC dispatch cost trend line: one cheap paired micro trial per
    backend (see bench_rpc_path.py), recorded like any other cell so
    benchmarks/trend.py inherits a ns/call regression gate.  The
    cooperative backends get a second ``+resilient`` cell — the same
    inline configuration under a full breakers + retry + bulkhead policy —
    so the breaker-aware admission cost (PR 7) has its own trend line.
    Errors are smoke failures — the microbenchmark exercising the fast
    path must not rot silently.

    PR 10 adds the **hook-toll cells**: a paired probe of the
    ``repro.core.instrument`` seam (hooks disabled — the shipped
    default — vs a no-op ``Hooks()`` installed) on one inline-path and
    one carrier-path backend.  The disabled seam's zero-cost claim is
    already enforced by the hard-gated plain ``rpc_path/<backend>``
    cells above (they always run hooks-disabled, so seam rot shows up
    against the committed baseline); the ``+hooks`` cells are
    **warn-only** — the no-op-dispatch toll is diagnostic, not a
    shipped configuration."""
    from .bench_rpc_path import (HOOK_PROBE_BACKENDS, INLINE_BACKENDS,
                                 measure_hook_toll, measure_rpc_cost,
                                 resilient_policy)
    out["rpc_path"] = {}
    variants = [(backend, None) for backend in BENCH_BACKENDS]
    variants += [(backend, "resilient") for backend in BENCH_BACKENDS
                 if backend in INLINE_BACKENDS]
    for backend, variant in variants:
        label = backend if variant is None else f"{backend}+{variant}"
        pol = None if variant is None else resilient_policy()
        try:
            # best-of-3 (vs SMOKE_TRIALS=2 elsewhere): the micro is cheap
            # (~tens of ms per trial) and min-of-3 stabilizes the
            # machine-absolute ns figure considerably
            trials = [round(measure_rpc_cost(
                backend, resilience=pol, iters=4,
                calls_per_req=32)["ns_per_call"], 1)
                for _ in range(max(SMOKE_TRIALS, 3))]
        except Exception as exc:  # noqa: BLE001 - cell isolation
            out["rpc_path"][label] = {"status": "error",
                                      "error": repr(exc)}
            out["failures"].append(f"rpc_path/{label}: {exc!r}")
            continue
        best = min(trials)  # lower is better: best-of mirrors the rps cells
        out["rpc_path"][label] = {"status": "ok", "ns_per_call": best,
                                  "trials": trials}
        out["records"].append({
            "key": f"rpc_path/{label}",
            "app": "_rpc_path",   # not a registry app: micro, app-agnostic
            "backend": backend,
            "metric": "ns_per_call",
            "unit": "ns",
            "direction": "lower",
            "noise": "micro",     # machine-absolute: wide clamps in trend
            "value": best,
            "trials": trials,
            "errors": 0,
        })
        print(f"rpc_path {label}: ns/call={best} trials={trials}",
              flush=True)
    for backend in HOOK_PROBE_BACKENDS:
        label = f"{backend}+hooks"
        try:
            probe = measure_hook_toll(backend, iters=4, calls_per_req=32,
                                      rounds=2)
        except Exception as exc:  # noqa: BLE001 - cell isolation
            out["rpc_path"][label] = {"status": "error",
                                      "error": repr(exc)}
            out["failures"].append(f"rpc_path/{label}: {exc!r}")
            continue
        on = round(probe["on_ns"], 1)
        out["rpc_path"][label] = {
            "status": "ok", "ns_per_call": on,
            "off_ns_per_call": round(probe["off_ns"], 1),
            "toll": round(probe["toll"], 3),
        }
        out["records"].append({
            "key": f"rpc_path/{label}",
            "app": "_rpc_path",
            "backend": backend,
            "metric": "ns_per_call",
            "unit": "ns",
            "direction": "lower",
            "noise": "micro",
            # warn-only: a no-op-dispatch toll is diagnostic data — the
            # hard zero-cost gate is the plain hooks-disabled cell above
            "gate": "warn-only",
            "value": on,
            "errors": 0,
        })
        print(f"rpc_path {label}: ns/call={on} "
              f"off={round(probe['off_ns'], 1)} "
              f"toll={round(probe['toll'], 3)}x", flush=True)


def run_smoke(apps: Optional[Sequence[str]] = None,
              json_path: Optional[str] = None,
              steal_probe: bool = True,
              quick: bool = False,
              baseline_path: Optional[str] = None) -> int:
    """Run the smoke matrix; write the artifact; return the exit code.

    ``quick`` halves the probe's round budget — the per-cell trials are
    already tiny — for local iteration on the harness itself.
    ``baseline_path`` additionally writes the artifact there on a fully
    green run (the ``run.py --smoke --update-baseline`` path, so refreshing
    the committed trend baseline is one reviewed command).
    """
    probe_rounds = max(PROBE_MAX_ROUNDS // 2, 2) if quick \
        else PROBE_MAX_ROUNDS
    apps = list(apps) if apps else list(APP_NAMES)
    out: Dict[str, Any] = {
        "schema_version": SCHEMA_VERSION,
        "backends": list(BENCH_BACKENDS),
        "apps": apps,
        "cells": {},
        "records": [],
        "parity": {},
        "steal_probe": {},
        "design_probes": {},
        "overload_probe": {},
        "failures": [],
    }
    for app_name in apps:
        requests = _fixed_requests(app_name)
        cells: Dict[str, Dict[str, Any]] = {}
        for backend in BENCH_BACKENDS:
            key = f"{app_name}/{backend}"
            try:
                cell = _smoke_cell(app_name, backend, requests)
            except Exception as exc:  # noqa: BLE001 - cell isolation
                cell = {"status": "error", "error": repr(exc)}
                out["failures"].append(f"{key}: {exc!r}")
            else:
                if cell["errors"]:
                    out["failures"].append(
                        f"{key}: {cell['errors']} request errors")
            cells[backend] = cell
            out["cells"][key] = {k: v for k, v in cell.items()
                                 if k != "results"}
            if cell.get("status") == "ok":
                # normalized cross-run records: what benchmarks/trend.py
                # diffs.  "direction" tells the gate which way is worse.
                out["records"].append({
                    "key": key,
                    "app": app_name,
                    "backend": backend,
                    "metric": "achieved_rps",
                    "unit": "rps",
                    "direction": "higher",
                    "value": cell["achieved_rps"],
                    "trials": cell["trial_rps"],
                    "errors": cell["errors"],
                })
                if cell.get("p99_ms") is not None:
                    # warn-only: a smoke-scale p99 is the tail of ~a hundred
                    # samples and swings several-x run-over-run on identical
                    # code, so it cannot support a hard gate — it is
                    # recorded and loudly warned on (trend surfaces any
                    # out-of-band move in the job log and trend-<app>.md);
                    # hard p99 gating belongs to the full bench via
                    # `trend.py --from-csv`, where tails have support.
                    out["records"].append({
                        "key": f"{key}/p99",
                        "app": app_name,
                        "backend": backend,
                        "metric": "p99_ms",
                        "unit": "ms",
                        "direction": "lower",
                        "gate": "warn-only",
                        "value": cell["p99_ms"],
                        "trials": cell["trial_p99_ms"],
                        "errors": cell["errors"],
                    })
            print(f"smoke {key}: {cell.get('status')} "
                  f"rps={cell.get('achieved_rps')} "
                  f"trials={cell.get('trial_rps')} "
                  f"errors={cell.get('errors')}", flush=True)
        # skewed-cache cells (PR 8): the Zipfian session-affine workload on
        # every backend, with the cache-aside hit/miss counters surfaced.
        # The hit rate is a property of the key distribution, not the
        # scheduler, so it lands as a warn-only gauge: a move means the
        # workload or the cache tier changed, not that a backend regressed.
        for backend in BENCH_BACKENDS:
            key = f"{app_name}/{backend}/cached"
            try:
                cell = _cache_cell(app_name, backend)
            except Exception as exc:  # noqa: BLE001 - cell isolation
                cell = {"status": "error", "error": repr(exc)}
                out["failures"].append(f"{key}: {exc!r}")
            else:
                if cell["errors"]:
                    out["failures"].append(
                        f"{key}: {cell['errors']} request errors")
            out["cells"][key] = cell
            if cell.get("status") == "ok" and cell["hit_rate"] is not None:
                out["records"].append({
                    "key": f"{key}/hit_rate",
                    "app": app_name,
                    "backend": backend,
                    "metric": "cache_hit_rate",
                    "unit": "frac",
                    "direction": "higher",
                    "gate": "warn-only",
                    "value": cell["hit_rate"],
                    "errors": cell["errors"],
                })
            print(f"smoke {key}: {cell.get('status')} "
                  f"rps={cell.get('achieved_rps')} "
                  f"hit_rate={cell.get('hit_rate')} "
                  f"(h={cell.get('cache_hits')} m={cell.get('cache_misses')}) "
                  f"errors={cell.get('errors')}", flush=True)
        # parity: every backend must reproduce the thread baseline bit-for-bit
        if cells.get(BASELINE, {}).get("status") == "ok":
            base = cells[BASELINE]["results"]
            mismatches = [b for b, c in cells.items()
                          if c.get("status") == "ok"
                          and c.get("results") != base]
            out["parity"][app_name] = {"ok": not mismatches,
                                       "mismatches": mismatches}
            if mismatches:
                out["failures"].append(f"{app_name}: parity violated vs "
                                       f"{BASELINE} by {mismatches}")
        else:
            # no healthy baseline to compare against; the thread-cell error
            # is already a recorded failure — don't pile on spurious ones.
            out["parity"][app_name] = {"ok": False,
                                       "mismatches": [],
                                       "note": f"{BASELINE} cell errored"}
        if steal_probe:
            try:
                probe = _steal_probe(app_name, max_rounds=probe_rounds)
            except Exception as exc:  # noqa: BLE001 - keep the artifact
                probe = {"status": "error", "error": repr(exc)}
                out["failures"].append(f"{app_name}/steal_probe: {exc!r}")
            out["steal_probe"][app_name] = probe
            print(f"steal probe {app_name}: "
                  f"fiber={probe.get('fiber_peak_rps')} "
                  f"fiber-steal={probe.get('fiber_steal_peak_rps')} "
                  f"ok={probe.get('ok')} "
                  f"(rounds={probe.get('rounds')})", flush=True)
        if steal_probe and app_name in DESIGN_PROBES:
            # the design-point probes ride the same flag: both are paired
            # A/B peak comparisons recorded as artifact data, not gates
            try:
                probes = _design_probes(app_name, max_rounds=probe_rounds)
            except Exception as exc:  # noqa: BLE001 - keep the artifact
                probes = {"status": "error", "error": repr(exc)}
                out["failures"].append(f"{app_name}/design_probes: {exc!r}")
            out["design_probes"][app_name] = probes
            for cand, p in probes.items():
                if not isinstance(p, dict) or "ratio" not in p:
                    continue
                print(f"design probe {app_name} [{p['workload']}]: "
                      f"{p['base']}={p['base_peak_rps']} "
                      f"{cand}={p['cand_peak_rps']} "
                      f"ratio={p['ratio']} (target {p['target']}) "
                      f"ok={p['ok']} (rounds={p['rounds']})", flush=True)
    if steal_probe and OVERLOAD_PROBE_APP in apps:
        # one paired overload cell per run (probe data, not a gate); its
        # goodput records feed trend.py with the wide "overload" noise band
        try:
            probe = _overload_probe(max_rounds=probe_rounds)
        except Exception as exc:  # noqa: BLE001 - keep the artifact
            probe = {"status": "error", "error": repr(exc)}
            out["failures"].append(f"overload_probe: {exc!r}")
        out["overload_probe"] = probe
        if "cand_peak_rps" in probe:
            for label, value in (("breakers-off", probe["base_peak_rps"]),
                                 ("breakers-on", probe["cand_peak_rps"])):
                out["records"].append({
                    "key": f"overload/{OVERLOAD_PROBE_APP}/"
                           f"{OVERLOAD_PROBE_BACKEND}/{label}",
                    "app": OVERLOAD_PROBE_APP,
                    "backend": OVERLOAD_PROBE_BACKEND,
                    "metric": "goodput_rps",
                    "unit": "rps",
                    "direction": "higher",
                    "noise": "overload",
                    # goodput past the peak is bimodal at smoke scale (one
                    # breaker trip erases half a short window) — surface
                    # out-of-band moves loudly, never fail the run on them
                    "gate": "warn-only",
                    "value": value,
                    "errors": 0,
                })
            print(f"overload probe {OVERLOAD_PROBE_APP} "
                  f"[{OVERLOAD_PROBE_BACKEND} @ {probe['overload_rps']}rps"
                  f"={OVERLOAD_MULTIPLE}x peak]: goodput "
                  f"breakers-off={probe['base_peak_rps']} "
                  f"breakers-on={probe['cand_peak_rps']} "
                  f"ratio={probe['ratio']} ok={probe['ok']} "
                  f"(opens={probe['breaker_opens']} "
                  f"to={probe['timeouts']} rtry={probe['retries']}, "
                  f"rounds={probe['rounds']})", flush=True)
        try:
            knee = _knee_probe()
        except Exception as exc:  # noqa: BLE001 - keep the artifact
            knee = {"status": "error", "error": repr(exc)}
            out["failures"].append(f"knee_probe: {exc!r}")
        out["knee_probe"] = knee
        if "knee_multiple" in knee:
            out["records"].append({
                "key": f"overload/{OVERLOAD_PROBE_APP}/"
                       f"{OVERLOAD_PROBE_BACKEND}/knee",
                "app": OVERLOAD_PROBE_APP,
                "backend": OVERLOAD_PROBE_BACKEND,
                "metric": "knee_multiple",
                "unit": "x",
                "direction": "higher",
                "noise": "overload",
                # the knee derives from goodput past the peak, which is
                # bimodal at smoke scale — surface moves, never gate
                "gate": "warn-only",
                "value": knee["knee_multiple"],
                "errors": 0,
            })
            print(f"knee probe {OVERLOAD_PROBE_APP} "
                  f"[{OVERLOAD_PROBE_BACKEND}]: "
                  f"knee={knee['knee_multiple']:g}x "
                  f"collapsed={knee['collapsed']} curve="
                  + "|".join(f"{p['multiple']:g}:{p['goodput_rps']:.0f}"
                             for p in knee["curve"]), flush=True)
    if steal_probe and FAULTS_PROBE_APP in apps:
        # the deterministic breakers-pay-off check (PR 9): HARD-gated —
        # the scenario is engineered so fail-fast must win, so a shrinking
        # win is a resilience-layer regression, not runner weather
        try:
            probe = _faults_probe(rounds=max(FAULTS_PROBE_ROUNDS // 2, 1)
                                  if quick else FAULTS_PROBE_ROUNDS)
        except Exception as exc:  # noqa: BLE001 - keep the artifact
            probe = {"status": "error", "error": repr(exc)}
            out["failures"].append(f"faults_probe: {exc!r}")
        out["faults_probe"] = probe
        if "win" in probe:
            if not probe["ok"]:
                out["failures"].append(
                    f"faults_probe: breaker win {probe['win']}x < "
                    f"{FAULTS_WIN_TARGET}x target under the sick-dependency "
                    f"scenario (on={probe['on_goodput_rps']} "
                    f"off={probe['off_goodput_rps']} goodput rps)")
            out["records"].append({
                # no "gate" field: this cell is hard-gated by the trend
                # diff, and the win is capped (see FAULTS_WIN_CAP) so the
                # gate compares bounded, stable values
                "key": f"faults/{FAULTS_PROBE_APP}/"
                       f"{FAULTS_PROBE_BACKEND}/breaker_win",
                "app": FAULTS_PROBE_APP,
                "backend": FAULTS_PROBE_BACKEND,
                "metric": "breaker_win",
                "unit": "x",
                "direction": "higher",
                "value": probe["win"],
                "trials": probe["wins"],
                "errors": 0,
            })
            for label, value in (("on", probe["on_goodput_rps"]),
                                 ("off", probe["off_goodput_rps"])):
                out["records"].append({
                    # context cells: absolute goodput under the fault is
                    # runner-dependent, so these stay warn-only; the
                    # gated claim is the ratio above
                    "key": f"faults/{FAULTS_PROBE_APP}/"
                           f"{FAULTS_PROBE_BACKEND}/goodput_{label}",
                    "app": FAULTS_PROBE_APP,
                    "backend": FAULTS_PROBE_BACKEND,
                    "metric": "goodput_rps",
                    "unit": "rps",
                    "direction": "higher",
                    "noise": "overload",
                    "gate": "warn-only",
                    "value": value,
                    "errors": 0,
                })
            print(f"faults probe {FAULTS_PROBE_APP} "
                  f"[{FAULTS_PROBE_BACKEND} @ {probe['rate_rps']}rps]: "
                  f"breaker win={probe['win']}x (raw={probe['raw_win']}x, "
                  f"target {FAULTS_WIN_TARGET}x) ok={probe['ok']} "
                  f"on={probe['on_goodput_rps']} "
                  f"off={probe['off_goodput_rps']} "
                  f"sick_opens={probe['sick_edge_opens']} "
                  f"healthy_opens={probe['healthy_edge_opens']} "
                  f"flt={probe['faults_injected']} "
                  f"(rounds={probe['rounds']})", flush=True)
    if steal_probe and PINNING_PROBE_APP in apps:
        # paired A/B of shard placement policy under the hot-shard Zipfian
        # workload: by-session (deterministic, state-affine) vs by-ticket
        # (spread-everything).  Probe data, not a gate — affinity trades
        # peak rps for placement determinism, and the honest number is the
        # point (warn-only records feed the trend like the overload cells).
        # two placement A/Bs: the keep-alive (warm, steady-state hits) mode
        # and the PR 9 cold-start-per-round mode (first-touch placement
        # included).  Distinct warn-only trend cells — the cold cells keep
        # their own baseline, so warm-mode history stays comparable.
        for cold in (False, True):
            slot = "pinning_probe_cold" if cold else "pinning_probe"
            suffix = "/cold" if cold else ""
            try:
                probe = _pinning_probe(max_rounds=probe_rounds,
                                       cold_start=cold)
            except Exception as exc:  # noqa: BLE001 - keep the artifact
                probe = {"status": "error", "error": repr(exc)}
                out["failures"].append(f"{slot}: {exc!r}")
            out[slot] = probe
            if "cand_peak_rps" not in probe:
                continue
            for label, value in (("by-ticket", probe["base_peak_rps"]),
                                 ("by-session", probe["cand_peak_rps"])):
                out["records"].append({
                    "key": f"pinning/{PINNING_PROBE_APP}/"
                           f"{PINNING_PROBE_BACKEND}/{label}{suffix}",
                    "app": PINNING_PROBE_APP,
                    "backend": PINNING_PROBE_BACKEND,
                    "metric": "peak_rps",
                    "unit": "rps",
                    "direction": "higher",
                    # a placement-policy A/B at smoke scale is a probe, and
                    # the skew concentration it measures is seed-dependent —
                    # surface moves in the trend, never fail the run
                    "gate": "warn-only",
                    "value": value,
                    "errors": 0,
                })
            print(f"pinning probe {PINNING_PROBE_APP} "
                  f"[{PINNING_PROBE_BACKEND} {probe['mode']}]: peak "
                  f"by-ticket={probe['base_peak_rps']} "
                  f"by-session={probe['cand_peak_rps']} "
                  f"ratio={probe['ratio']} ok={probe['ok']} "
                  f"(hits={probe['cache_hits']} "
                  f"misses={probe['cache_misses']}, "
                  f"rounds={probe['rounds']})", flush=True)
    _rpc_path_records(out)
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2, sort_keys=True)
        print(f"wrote {json_path}", flush=True)
    if out["failures"]:
        print("SMOKE FAILURES:", file=sys.stderr)
        for fail in out["failures"]:
            print(f"  {fail}", file=sys.stderr)
        if baseline_path:
            print(f"NOT updating baseline {baseline_path}: run not green",
                  file=sys.stderr)
        return 1
    if baseline_path:
        with open(baseline_path, "w") as f:
            json.dump(out, f, indent=2, sort_keys=True)
        print(f"updated trend baseline {baseline_path}", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(run_smoke())
