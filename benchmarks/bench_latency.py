"""Paper Figure 2: 99th-percentile latency vs offered request rate.

Rates are swept from low load up to just beneath the *thread* backend's peak
throughput (the paper's protocol), for each of the four workloads.
"""
from __future__ import annotations

from typing import List

from repro.apps import WORKLOADS, build_socialnetwork, make_request_factory
from repro.core import latency_sweep, run_trial

from .bench_throughput import _app_for, measure_peak


def run(quick: bool = False) -> List[str]:
    duration = 0.6 if quick else 1.2
    n_points = 3 if quick else 5
    rows: List[str] = []
    for workload in WORKLOADS:
        thread_peak = measure_peak("thread", workload,
                                   duration=0.5 if quick else 0.8)
        # sweep up to ~90% of the thread peak, as in the paper
        rates = [thread_peak * f for f in
                 [0.1, 0.3, 0.5, 0.7, 0.9][:n_points]]
        for backend in ("thread", "fiber"):
            with _app_for(backend) as app:
                run_trial(app, make_request_factory(workload), rate=100,
                          duration=0.3, seed=7)  # warmup
                trials = latency_sweep(app, make_request_factory(workload),
                                       rates, duration=duration)
            for tr in trials:
                rows.append(
                    f"p99_latency/{workload}/{backend}@{tr.offered_rps:.0f}rps,"
                    f"{tr.p99 * 1e6:.1f},p50_us={tr.p50 * 1e6:.1f}")
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
