"""Paper Figure 2: 99th-percentile latency vs offered request rate.

Rates are swept from low load up to just beneath the *thread* backend's peak
throughput (the paper's protocol), for each workload of each registered app,
under every backend in the matrix (``BACKENDS`` — thread, thread-pool,
fiber, fiber-steal, fiber-batch, fiber-batch-cq, event-loop,
event-loop-shard), so the latency cliffs of all eight dispatch mechanisms
line up on a common x-axis.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

from repro.apps import APP_NAMES, build_bench_app, get_app_def
from repro.core import latency_sweep, warmup

from .bench_throughput import BACKENDS, measure_peak


def run(quick: bool = False,
        apps: Optional[Sequence[str]] = None) -> List[str]:
    duration = 0.6 if quick else 1.2
    n_points = 3 if quick else 5
    apps = list(apps) if apps else list(APP_NAMES)
    rows: List[str] = []
    for app_name in apps:
        d = get_app_def(app_name)
        for workload in d.workloads:
            thread_peak = measure_peak(app_name, "thread", workload,
                                       duration=0.5 if quick else 0.8)
            # sweep up to ~90% of the thread peak, as in the paper
            rates = [thread_peak * f for f in
                     [0.1, 0.3, 0.5, 0.7, 0.9][:n_points]]
            for backend in BACKENDS:
                with build_bench_app(app_name, backend) as app:
                    warmup(app, d.make_request_factory(workload), seed=7)
                    trials = latency_sweep(app,
                                           d.make_request_factory(workload),
                                           rates, duration=duration)
                for tr in trials:
                    rows.append(
                        f"p99_latency/{app_name}/{workload}/{backend}"
                        f"@{tr.offered_rps:.0f}rps,"
                        f"{tr.p99 * 1e6:.1f},p50_us={tr.p50 * 1e6:.1f}")
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
